"""Adam (for the non-EASGD baseline runs and examples)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray
    m: object
    v: object


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0):
    def init(params):
        z = lambda: jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamState(jnp.zeros((), jnp.int32), z(), z())

    def update(grads, state, params):
        t = state.step + 1
        lr_t = lr(state.step) if callable(lr) else lr
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state.m, grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) *
            jnp.square(g.astype(jnp.float32)), state.v, grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, m_, v_):
            step_ = lr_t * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                step_ = step_ + lr_t * weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step_).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, m, v)
        return new_params, AdamState(t, m, v)

    return init, update
