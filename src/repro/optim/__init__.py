from repro.optim.sgd import sgd, momentum_sgd
from repro.optim.adam import adam
from repro.optim.schedule import (
    constant, linear_warmup_cosine, step_decay, Schedule,
)
