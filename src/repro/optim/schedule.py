"""Learning-rate schedules."""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Schedule:
    fn: object

    def __call__(self, step):
        return self.fn(step)


def constant(lr: float) -> Schedule:
    return Schedule(lambda step: jnp.asarray(lr, jnp.float32))


def linear_warmup_cosine(peak_lr: float, warmup: int, total: int,
                         floor: float = 0.0) -> Schedule:
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (peak_lr - floor) * 0.5 * (1 + jnp.cos(math.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return Schedule(fn)


def step_decay(lr: float, decay: float, every: int) -> Schedule:
    def fn(step):
        k = jnp.asarray(step, jnp.float32) // every
        return lr * (decay ** k)
    return Schedule(fn)
