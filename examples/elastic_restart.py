"""Fault-tolerance demo: checkpoint/restart + elastic pod rescale.

Trains Sync EASGD with 2 pods, "crashes", restores from the checkpoint,
rescales to 3 pods (the joiner seeds from the center weight — EASGD's own
semantics), and keeps training. Loss continuity is asserted.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import warnings

warnings.filterwarnings("ignore")

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.core.easgd import EASGDConfig
from repro.core.elastic import ElasticConfig
from repro.core import elastic
from repro.data import ShardedPipeline, SyntheticLMStream
from repro.ft import rescale_pods
from repro.models import transformer as tfm
from repro.models.common import init_params


def main():
    cfg = configs.get("recurrentgemma-2b").reduced
    ecfg = ElasticConfig(easgd=EASGDConfig(eta=0.05, rho=0.02, mu=0.9),
                         packed=False)
    B, S = 4, 32
    gfn = jax.jit(jax.vmap(jax.value_and_grad(
        lambda p, b: tfm.lm_loss(cfg, p, b), has_aux=True)))
    step_fn = jax.jit(lambda st, g: elastic.apply_gradients(st, g, ecfg))

    def make_pipe(n_pods, start=0):
        p = ShardedPipeline(
            lambda shard, n: SyntheticLMStream(cfg.vocab_size, S, B, seed=5,
                                               shard=shard, n_shards=n),
            n_pods=n_pods, start_step=start)
        return p

    params = init_params(tfm.model_defs(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    state = elastic.init(params, ecfg, n_pods=2)
    ckpt = CheckpointManager(tempfile.mkdtemp(prefix="elastic_demo_"))
    pipe = make_pipe(2)

    losses = []
    for step in range(12):
        batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
        (loss, _), grads = gfn(state.params, batch)
        state = step_fn(state, grads)
        losses.append(float(jnp.mean(loss)))
    ckpt.save(12, state, extra={"data_step": 12})
    print(f"phase 1 (2 pods): loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          "checkpointed and 'crashed'")

    # ---- restart: restore, then ELASTICALLY grow to 3 pods ---------------
    template = elastic.init(params, ecfg, n_pods=2)
    restored, meta = ckpt.restore(template)
    state2 = rescale_pods(restored, 3)
    np.testing.assert_allclose(
        np.asarray(state2.params["embed"][2], np.float32),
        np.asarray(restored.center["embed"], np.float32), rtol=1e-6)
    print("restored at step", meta["extra"]["data_step"],
          "and grew to 3 pods (joiner seeded from the center weight)")

    pipe = make_pipe(3, start=meta["extra"]["data_step"])
    losses2 = []
    for step in range(12):
        batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
        (loss, _), grads = gfn(state2.params, batch)
        state2 = step_fn(state2, grads)
        losses2.append(float(jnp.mean(loss)))
    print(f"phase 2 (3 pods): loss {losses2[0]:.3f} -> {losses2[-1]:.3f}")
    assert losses2[0] < losses[0] + 0.5, "loss continuity broken by restart"
    print("loss continuity across crash+rescale: OK")


if __name__ == "__main__":
    main()
