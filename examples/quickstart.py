"""Quickstart: train a small gemma3-family LM with multi-pod Sync EASGD on
CPU host devices, then decode from it.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py
"""
import warnings

warnings.filterwarnings("ignore")

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.easgd import EASGDConfig
from repro.core.elastic import ElasticConfig
from repro.data import ShardedPipeline, SyntheticLMStream
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tfm
from repro.runtime.train import build_train_step


def main():
    n_dev = jax.device_count()
    print(f"devices: {n_dev}")
    if n_dev >= 8:
        mesh = make_host_mesh(n_data=2, n_model=2, n_pods=2)
        n_pods = 2
    else:
        mesh = make_host_mesh(n_data=1, n_model=1)
        n_pods = 1

    cfg = configs.get("gemma3-4b").reduced
    ecfg = ElasticConfig(easgd=EASGDConfig(eta=0.15, rho=0.02, mu=0.9))
    B, S = 16, 32
    build = build_train_step(cfg, ecfg, mesh, n_pods=n_pods,
                             per_pod_batch=B // n_pods, seq=S)
    state = build.init_state()

    pipe = ShardedPipeline(
        lambda shard, n: SyntheticLMStream(cfg.vocab_size, S, B // n_pods,
                                           seed=3, shard=shard, n_shards=n),
        n_pods=n_pods).start()
    print("training 40 steps of Sync EASGD "
          f"({n_pods} pods × {B // n_pods} seqs × {S} tokens)…")
    try:
        for step in range(40):
            batch = {k: jnp.asarray(v) for k, v in pipe.next().items()}
            state, metrics = build.step(state, batch)
            if step % 8 == 0:
                print(f"  step {step:3d}  loss {float(metrics['loss']):.4f} "
                      f"acc {float(metrics['accuracy']):.3f}")
    finally:
        pipe.stop()
    print(f"final loss {float(metrics['loss']):.4f}")

    # decode a few tokens from the CENTER weights (the durable consensus)
    params = jax.tree_util.tree_map(lambda c: c, state.center)
    caches = tfm.init_caches(cfg, 1, max_len=16)
    tok = jnp.zeros((1, 1), jnp.int32)
    out = []
    for t in range(8):
        logits, caches = tfm.decode_step(
            cfg, params, tok, caches, jnp.asarray([t], jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
    print("greedy decode from center weights:", out)


if __name__ == "__main__":
    main()
