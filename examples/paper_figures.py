"""Reproduce the paper's Figure 6/8 comparison: the nine distributed-SGD
algorithms racing to a target error (real training + modeled time), and
print the ASCII error-vs-time curves.

    PYTHONPATH=src python examples/paper_figures.py [--iters 2000]
"""
import warnings

warnings.filterwarnings("ignore")

import argparse

from benchmarks.common import default_engine
from repro.core.async_engine import ALGORITHMS


def ascii_curve(history, width=48, t_max=None):
    if not history:
        return ""
    t_max = t_max or history[-1][0]
    cells = [" "] * width
    for t, _, err in history:
        x = min(int(t / t_max * (width - 1)), width - 1)
        c = "#" if err > 0.5 else ("+" if err > 0.3 else ".")
        cells[x] = c
    return "".join(cells)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=2000)
    args = ap.parse_args()

    eng = default_engine(seed=0)
    results = {}
    t_max = 0.0
    for algo in ALGORITHMS:
        res = eng.run(algo, total_iters=args.iters)
        results[algo] = res
        t_max = max(t_max, res.total_time_s)
        print(f"ran {algo:16s} final_err={res.final_metric:.3f} "
              f"time={res.total_time_s:.2f}s")

    print("\nerror over modeled time ('#'>0.5, '+'>0.3, '.'<=0.3):")
    for algo, res in sorted(results.items(),
                            key=lambda kv: kv[1].final_metric):
        print(f"  {algo:16s} |{ascii_curve(res.history, t_max=t_max)}|")

    print("\npaper claims (Fig 6/8):")
    def t_to(algo, target=0.30):
        for t, _, e in results[algo].history:
            if e <= target:
                return t
        return float("inf")
    pairs = [("async_easgd", "async_sgd"), ("async_measgd", "async_msgd"),
             ("hogwild_easgd", "hogwild_sgd"),
             ("sync_easgd", "original_easgd")]
    for ours, theirs in pairs:
        ok = t_to(ours) <= t_to(theirs)
        print(f"  {ours} faster than {theirs}: "
          f"{'REPRODUCED' if ok else 'NOT reproduced'} "
          f"({t_to(ours):.2f}s vs {t_to(theirs):.2f}s)")


if __name__ == "__main__":
    main()
