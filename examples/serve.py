"""Serve a small model with batched requests through the continuous-
batching engine (the same decode step the dry-run lowers at 32k/500k).

    PYTHONPATH=src python examples/serve.py --arch mamba2-780m
"""
import warnings

warnings.filterwarnings("ignore")

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import transformer as tfm
from repro.models.common import init_params
from repro.runtime.serve import BatchingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()

    cfg = configs.get(args.arch).reduced
    params = init_params(tfm.model_defs(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    eng = BatchingEngine(cfg, params, batch=args.slots, max_len=64)

    rng = np.random.RandomState(0)
    pending = [list(rng.randint(0, cfg.vocab_size, size=rng.randint(3, 8)))
               for _ in range(args.requests)]
    t0 = time.time()
    done_count = 0
    submitted = {}
    while done_count < args.requests:
        while pending:
            rid = eng.submit(pending[0])
            if rid is None:
                break                      # no free slot — decode to drain
            submitted[rid] = pending.pop(0)
        finished = eng.step(stop_len=args.gen)
        for rid in finished:
            done_count += 1
            print(f"req {rid}: prompt={submitted[rid][:4]}… -> "
                  f"{eng.outputs[rid]}")
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in eng.outputs.values())
    print(f"\nserved {args.requests} requests, {total_tokens} tokens "
          f"in {dt:.1f}s ({total_tokens/dt:.1f} tok/s on 1 CPU core; "
          f"the dry-run lowers this same step at batch 128 × 32k context)")


if __name__ == "__main__":
    main()
